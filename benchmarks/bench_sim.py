"""Simulator-engine benchmark: event-driven (dt=None) vs fixed-quantum.

Runs Fig.5-style synthetic tasksets over growing horizons, records wall
time, events/sec and the speedup of the exact engine over the quantum
engine, and writes the table to BENCH_sim.json at the repo root. The
quantum engine is O(horizon/dt x cores x jobs); the event engine is
O(events) — and since the MemoryModel refactor a steady-state event
touches only dirty cores, so the per-event cost no longer scales with
cores^2. The 16-core workload tracks that: `entries` keeps one summary
per `--stage` label (before_memmodel / after_memmodel) so the speedup
of the incremental co-runner refactor is recorded in-repo.

    PYTHONPATH=src python benchmarks/bench_sim.py [--smoke] [--out PATH]
        [--profile] [--stage LABEL]

--smoke caps the horizon at 1,000 ms (CI perf sanity: asserts the event
engine wins by >= 5x there; the full run's >= 10x criterion applies to
the 10,000 ms cell).

--profile times the event loop's phases (fixed_point / rates /
push_updates / advance / events) on the 16-core workload and writes the
breakdown under "profile", so the next hot spot is measurable.
"""
from __future__ import annotations

import argparse
import dataclasses
import gc
import json
import os
import time

from repro.core import rta as core_rta
from repro.core.gang import BETask, RTTask
from repro.core.sim import Simulator, matrix_interference
from repro.obs.margins import overall
from repro.obs.metrics import MetricsRegistry

try:
    from benchmarks.run import write_bench_json
except ImportError:          # run as `python benchmarks/bench_sim.py`
    from run import write_bench_json

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def fig5_style_taskset():
    """benchmarks/fig5_synthetic.py's taskset (restated: fresh task uids
    per call keep Simulator instances independent)."""
    t1 = RTTask("tau1", wcet=3.5, period=20, cores=(0, 1), prio=2,
                mem_budget=0.1)
    t2 = RTTask("tau2", wcet=6.5, period=30, cores=(2, 3), prio=1,
                mem_budget=0.1)
    bem = BETask("be_mem", cores=(0, 1, 2, 3), mem_rate=1.0)
    bec = BETask("be_cpu", cores=(0, 1, 2, 3), mem_rate=0.01)
    intf = matrix_interference({
        ("tau1", "tau2"): 2.0, ("tau2", "tau1"): 2.0,
        ("tau1", "be_mem"): 1.5, ("tau2", "be_mem"): 1.5,
    })
    return 4, [t1, t2], [bem, bec], intf


def cores16_taskset():
    """The ISSUE's 16-core workload: 4 RT gangs of width 4 on disjoint
    core blocks plus 4 machine-wide best-effort tasks under reactive
    throttling — the per-event co-runner rescan used to cost O(cores^2)
    here, which is what the incremental MemoryModel removes."""
    rts, table = [], {}
    for i in range(4):
        rts.append(RTTask(f"g{i}", wcet=3.0 + 0.7 * i,
                          period=20.0 + 10.0 * i,
                          cores=tuple(range(4 * i, 4 * i + 4)),
                          prio=10 - i, mem_budget=0.3))
    bes = [BETask(f"be{i}", cores=tuple(range(16)),
                  mem_rate=(1.0 if i % 2 == 0 else 0.05))
           for i in range(4)]
    for a in rts:
        for b in rts:
            if a.name != b.name:
                table[(a.name, b.name)] = 1.3
        table[(a.name, "be0")] = 1.6
        table[(a.name, "be2")] = 1.6
    return 16, rts, bes, matrix_interference(table)


WORKLOADS = {"fig5_4c": fig5_style_taskset, "cores16": cores16_taskset}

# sound WCET inflation per workload for the margin bounds below: RT
# gangs run one-at-a-time, so an RT thread only ever co-runs with the
# best-effort fillers, and the MemoryModel slowdown is the max
# interference factor against any co-present BE occupant — 1.5 for
# fig5 (tauX vs be_mem), 1.6 for cores16 (gX vs be0/be2)
RTA_INFLATION = {"fig5_4c": 1.5, "cores16": 1.6}


def rta_bounds_for(workload: str) -> dict:
    """Per-task analytic response-time bounds (ms) for the workload:
    standard gang RTA over BE-interference-inflated WCET clones —
    measured responses must stay under these (DESIGN.md §12.3)."""
    _, rts, _, _ = WORKLOADS[workload]()
    f = RTA_INFLATION[workload]
    inflated = [dataclasses.replace(t, wcet=t.wcet * f) for t in rts]
    res = core_rta.schedulable(inflated)
    assert all(v["ok"] for v in res.values()), \
        f"{workload}: inflated-WCET RTA must accept (bounds exist)"
    return {k: v["wcrt"] for k, v in res.items()}


def run_engine(workload, dt, horizon: float, profile: bool = False,
               rta_bounds: dict = None, metrics=None):
    n, rts, bes, intf = WORKLOADS[workload]()
    sim = Simulator(n, rts, be_tasks=bes, interference=intf,
                    rt_gang_enabled=True, dt=dt, throttle_mode="reactive",
                    rta_bounds=rta_bounds, metrics=metrics)
    if profile:
        sim.profile = True
    t0 = time.perf_counter()
    r = sim.run(horizon)
    wall = time.perf_counter() - t0
    return r, wall, sim


def bench_horizon(workload: str, horizon: float, dt: float = 0.05,
                  repeats: int = 3) -> dict:
    """Best-of-``repeats`` wall time for the event engine (the runs are
    deterministic; repeating filters scheduler noise on loaded hosts).
    The quantum engine runs once — it is 1-2 orders slower and only its
    order of magnitude matters."""
    bounds = rta_bounds_for(workload)
    e_wall = float("inf")
    e = None
    for _ in range(max(1, repeats)):
        e_run, w, _ = run_engine(workload, None, horizon,
                                 rta_bounds=bounds)
        e = e_run
        e_wall = min(e_wall, w)
    # a quantum completion is stamped up to one dt late: add the
    # discretization slop to the bounds before comparing (margins.py)
    q_bounds = {k: b + dt for k, b in bounds.items()}
    q, q_wall, _ = run_engine(workload, dt, horizon, rta_bounds=q_bounds)
    jobs = sum(len(v) for v in e.response_times.values())
    row = {
        "workload": workload,
        "horizon_ms": horizon,
        "quantum_dt_ms": dt,
        "quantum_wall_s": round(q_wall, 4),
        "event_wall_s": round(e_wall, 4),
        "speedup": round(q_wall / e_wall, 2) if e_wall > 0 else None,
        "events": e.events,
        "events_per_sec": round(e.events / e_wall) if e_wall > 0 else None,
        "quantum_steps": int(round(horizon / dt)),
        "jobs_completed": jobs,
        "wcrt_quantum": {k: max(v) for k, v in q.response_times.items()},
        "wcrt_event": {k: max(v) for k, v in e.response_times.items()},
        "wcrt_max_gap_ms": round(max(
            abs(max(q.response_times[k]) - max(e.response_times[k]))
            for k in e.response_times), 5),
        "misses_equal": q.deadline_misses == e.deadline_misses,
        "rta_margins_event": e.rta_margins,
        "rta_margins_quantum": q.rta_margins,
        "rta_margin": overall(e.rta_margins),
    }
    return row


def profile_event_loop(workload: str, horizon: float) -> dict:
    """Per-phase wall-time breakdown of the event loop (engines that
    predate phase profiling report {"unsupported": true})."""
    r, wall, sim = run_engine(workload, None, horizon, profile=True)
    eng = getattr(sim, "last_engine", None)
    phases = getattr(eng, "phase_wall", None)
    out = {"workload": workload, "horizon_ms": horizon, "events": r.events,
           "wall_s": round(wall, 4)}
    if not phases:
        out["unsupported"] = True
        return out
    total = sum(phases.values()) or 1.0
    releases = max(1, getattr(eng, "releases", 1))
    out["phases"] = {
        k: {"wall_s": round(v, 4),
            "frac": round(v / total, 3),
            "us_per_release": round(1e6 * v / releases, 2)}
        for k, v in sorted(phases.items(), key=lambda kv: -kv[1])}
    out["releases"] = releases
    return out


def obs_overhead(horizon: float, repeats: int = 12) -> dict:
    """Instrumented-vs-bare event-engine throughput on the 16-core
    workload (ISSUE satellite: the enabled-metrics hot path is plain
    ``counter.value += 1`` on pre-fetched instruments, and this entry
    keeps it honest — CI asserts the cost stays under 5% events/s).
    ``metrics=None`` hands every component a detached (enabled=False)
    registry, which is the bare baseline.

    Measuring a ~0–1% effect to 5% precision on a noisy shared host
    takes four defenses at once (each was tried alone and failed):
    CPU time (``time.process_time``; co-tenant load spikes swing
    single wall-clock runs ±35% and survive min-of-N), a
    ``gc.collect`` before every timed run (collection pauses
    otherwise land in random runs), adjacent bare/instrumented pairs
    scored by their RATIO (cancels the slow drift of the CPU-time
    floor that defeats best-of-N), with the order alternated between
    repetitions (the second run of a pair is systematically slower),
    and an interquartile-trimmed mean over the pair ratios (kills the
    remaining spikes). Measured spread of the result: ±1%."""
    ratios = []
    cpu_bare = float("inf")
    events = 0
    for rep in range(max(2, repeats)):
        order = (False, True) if rep % 2 == 0 else (True, False)
        pair = {}
        for metrics_on in order:
            reg = MetricsRegistry() if metrics_on else None
            gc.collect()
            c0 = time.process_time()
            r, _, _ = run_engine("cores16", None, horizon, metrics=reg)
            pair[metrics_on] = time.process_time() - c0
            events = r.events
        cpu_bare = min(cpu_bare, pair[False])
        ratios.append(pair[True] / pair[False])
    ratios.sort()
    k = len(ratios) // 4
    core = ratios[k:len(ratios) - k]
    overhead = sum(core) / len(core) - 1.0
    bare_eps = events / cpu_bare
    return {
        "workload": "cores16",
        "horizon_ms": horizon,
        "events": events,
        "repeats": max(2, repeats),
        "clock": "process_time",
        "bare_events_per_sec": round(bare_eps),
        "metrics_events_per_sec": round(bare_eps / (1.0 + overhead)),
        "overhead_frac": round(overhead, 4),
    }


def grid_wall_clock(repeats: int = 3, reps_per_cell: int = 75) -> dict:
    """The acceptance grid's RTA fixed-point verdict phase, scalar vs
    batched (DESIGN.md §13): generate the full plain-column grid
    workload (3 machine sizes x 9 utils x ``reps_per_cell`` tasksets,
    same seeds as ``vgang.grid``), collapse every taskset to its dense
    single-core-equivalent rows ONCE, then time the two interchangeable
    verdict phases over the precollapsed rows —

    * scalar: the per-lane Audsley loop (``core.rta._fixed_point``)
      exactly as the scalar ``accepts`` path runs it, and
    * batched: ``pad_rows`` + the masked vectorized kernel +
      ``accept_bits``.

    Collapse/formation are excluded from both sides: they are shared
    scalar preprocessing, identical in either path. Best-of-``repeats``
    (the kernel is warm after the first pass); verdicts are asserted
    equal before timing is trusted. The end-to-end ``accepts`` numbers
    (which include the shared scalar collapse) are recorded alongside
    under ``end_to_end``."""
    import random as _random

    from repro.analysis import batched_rta as _bat
    from repro.core.rta import _fixed_point
    from repro.launch.sweep import taskset_seed
    from repro.vgang.formation import (assign_priorities,
                                       intensity_interference,
                                       singleton_vgangs)
    from repro.vgang.grid import n_tasks_for, random_vgang_taskset
    from repro.vgang.rta import _collapse_rows
    from repro.vgang.rta import accepts as vg_accepts
    from repro.vgang.rta import batched_accepts as vg_batched_accepts

    utils = (0.4, 0.7, 0.9, 1.0, 1.1, 1.2, 1.4, 1.6, 2.0)
    vgang_sets, intfs, rows = [], [], []
    for m in (4, 8, 16):
        n_tasks = n_tasks_for(m)
        for u in utils:
            for k in range(reps_per_cell):
                rng = _random.Random(taskset_seed(0, k, u))
                tasks = random_vgang_taskset(rng, m, n_tasks, u, "mixed")
                intf = intensity_interference(tasks, 0.5)
                vgangs = assign_priorities(singleton_vgangs(tasks))
                vgang_sets.append(vgangs)
                intfs.append(intf)
                rows.append(_collapse_rows(vgangs, intf))

    def scalar_pass():
        bits = []
        for row in rows:
            ok = True
            for (_, c, p, prio) in row:
                hp = [(pj, cj) for (_, cj, pj, prj) in row if prj > prio]
                R = _fixed_point(c, hp, p, 10_000)
                if R is None or R > p + 1e-12:
                    ok = False
            bits.append(ok)
        return bits

    def batched_pass():
        batch = _bat.pad_rows(rows)
        R = _bat.fixed_point(batch)
        return _bat.accept_bits(batch, R).tolist()

    assert scalar_pass() == batched_pass(), \
        "batched fixed-point verdicts diverge from scalar"

    def best_of(fn):
        w = float("inf")
        for _ in range(max(1, repeats)):
            gc.collect()
            t0 = time.perf_counter()
            fn()
            w = min(w, time.perf_counter() - t0)
        return w

    scalar_s = best_of(scalar_pass)
    batched_s = best_of(batched_pass)
    e2e_scalar = best_of(lambda: [vg_accepts(v, i)
                                  for v, i in zip(vgang_sets, intfs)])
    e2e_batched = best_of(lambda: vg_batched_accepts(vgang_sets, intfs))
    return {
        "workload": "vgang grid, plain column, 3 machine sizes x "
                    f"{len(utils)} utils x {reps_per_cell} tasksets",
        "n_tasksets": len(rows),
        "n_lanes": sum(len(r) for r in rows),
        "repeats": max(1, repeats),
        "scalar_ms": round(1e3 * scalar_s, 2),
        "batched_ms": round(1e3 * batched_s, 2),
        "speedup_vs_scalar": round(scalar_s / batched_s, 2),
        "end_to_end": {
            "scalar_accepts_ms": round(1e3 * e2e_scalar, 2),
            "batched_accepts_ms": round(1e3 * e2e_batched, 2),
            "speedup": round(e2e_scalar / e2e_batched, 2),
        },
    }


def trace_modes(horizon: float) -> dict:
    """Both engines with tracing on vs off (``Simulator(trace=False)``):
    asserts the SimResult payloads (everything but the timeline itself)
    are byte-identical, and records the trace-off walls — the mode the
    grid/sweep Monte-Carlo sim-checks run in."""
    out = {"horizon_ms": horizon, "rows": []}
    for workload in ("fig5_4c", "cores16"):
        n, rts, bes, intf = WORKLOADS[workload]()
        for dt in (None, 0.05):
            walls = {}
            payload = {}
            for tr in (True, False):
                sim = Simulator(n, rts, be_tasks=bes, interference=intf,
                                rt_gang_enabled=True, dt=dt,
                                throttle_mode="reactive", trace=tr)
                t0 = time.perf_counter()
                r = sim.run(horizon)
                walls[tr] = time.perf_counter() - t0
                d = dataclasses.asdict(r)
                d.pop("trace")
                payload[tr] = json.dumps(d, sort_keys=True, default=repr)
            assert payload[True] == payload[False], \
                f"{workload} dt={dt}: trace=False changed the SimResult"
            out["rows"].append({
                "workload": workload,
                "engine": "event" if dt is None else "quantum",
                "trace_on_wall_s": round(walls[True], 4),
                "trace_off_wall_s": round(walls[False], 4),
                "identical_result": True,
            })
    return out


# config fields this surface exposes as flags (DESIGN.md §14.2)
BENCH_SIM_FLAG_PATHS = ("smoke", "output.profile", "output.stage",
                        "output.out")
BENCH_SIM_FLAG_HELPS = {
    "smoke": "short horizons only; assert >=5x at 1,000 ms",
    "output.profile": "record the event-loop phase breakdown",
    "output.stage": "label this run in the persistent 'entries' map "
                    "(e.g. before_memmodel / after_memmodel)",
    "output.out": "output JSON path (default BENCH_sim.json)",
}


def resolve_bench_sim_config(argv=None):
    from repro.experiment import (ExperimentConfig, add_flags, cli_main,
                                  default_bench_sim_config, derive_flags)
    ap = argparse.ArgumentParser()
    base = default_bench_sim_config()
    flags = derive_flags(ExperimentConfig, BENCH_SIM_FLAG_PATHS,
                         helps=BENCH_SIM_FLAG_HELPS)
    add_flags(ap, flags, base)
    return cli_main(ap, flags, base, argv, expected_kind="bench_sim")


def main():
    cfg = resolve_bench_sim_config()
    smoke = cfg.smoke
    out_path = cfg.output.out or os.path.join(ROOT, "BENCH_sim.json")

    horizons = [120.0, 1000.0] if smoke \
        else [120.0, 1000.0, 10000.0]
    rows = []
    for h in horizons:
        row = bench_horizon("fig5_4c", h)
        rows.append(row)
        print(json.dumps(row))

    h16 = 1000.0 if smoke else 2000.0
    row16 = bench_horizon("cores16", h16)
    print(json.dumps(row16))

    # decoupled from h16: short smoke runs are noise-dominated, and the
    # overhead entry must be stable enough for CI's 5% assert
    oh = obs_overhead(2000.0)
    print(json.dumps(oh))

    # the analysis fast path (DESIGN.md §13): grid RTA verdict phase
    # scalar vs batched, and trace-on vs trace-off parity + walls
    gw = grid_wall_clock()
    print(json.dumps(gw))
    tm = trace_modes(h16)
    print(json.dumps(tm))

    out = {
        "bench": "sim_engines",
        "taskset": "fig5_synthetic (2 RT gangs + 2 BE, reactive throttle)",
        "rows": rows,
        "rows_16c": [row16],
        "obs_overhead": oh,
        "grid_wall_clock": gw,
        "trace_modes": tm,
    }
    if cfg.output.profile:
        out["profile"] = profile_event_loop("cores16", h16)
        print(json.dumps(out["profile"]))

    # persistent per-stage summary: lets the repo carry a before/after
    # record of engine-refactor speedups on the 16-core workload
    entries = {}
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                entries = json.load(f).get("entries", {})
        except (json.JSONDecodeError, OSError):
            entries = {}
    if cfg.output.stage:
        entry = {"workload": "cores16", "horizon_ms": h16,
                 "events": row16["events"],
                 "event_wall_s": row16["event_wall_s"],
                 "events_per_sec": row16["events_per_sec"]}
        base = entries.get("before_memmodel")
        if base and cfg.output.stage != "before_memmodel" and \
                base.get("events_per_sec"):
            entry["speedup_vs_before"] = round(
                row16["events_per_sec"] / base["events_per_sec"], 2)
        entries[cfg.output.stage] = entry
    if entries:
        out["entries"] = entries

    write_bench_json(out_path, out, config=cfg)
    print(f"wrote {out_path}")

    last = rows[-1]
    target = 5.0 if smoke else 10.0
    assert last["misses_equal"], "engines disagree on deadline misses"
    assert last["speedup"] >= target, \
        f"speedup {last['speedup']}x below {target}x at {last['horizon_ms']}ms"
    for r in rows + [row16]:
        assert r["rta_margin"]["negative"] == 0, \
            f"negative RTA margin at {r['workload']}/{r['horizon_ms']}ms"
    assert oh["metrics_events_per_sec"] >= 0.95 * oh["bare_events_per_sec"], \
        f"metrics overhead {oh['overhead_frac']:.1%} exceeds 5% events/s"
    assert gw["speedup_vs_scalar"] >= 5.0, \
        f"batched RTA {gw['speedup_vs_scalar']}x below the 5x floor"
    print(f"OK: {last['speedup']}x at {last['horizon_ms']}ms "
          f"({last['events_per_sec']} events/s); 16c: "
          f"{row16['events_per_sec']} events/s; obs overhead "
          f"{oh['overhead_frac']:.1%}; worst margin "
          f"{row16['rta_margin']['worst_margin']}ms")


if __name__ == "__main__":
    main()
