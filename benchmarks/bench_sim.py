"""Simulator-engine benchmark: event-driven (dt=None) vs fixed-quantum.

Runs Fig.5-style synthetic tasksets over growing horizons, records wall
time, events/sec and the speedup of the exact engine over the quantum
engine, and writes the table to BENCH_sim.json at the repo root. The
quantum engine is O(horizon/dt x cores x jobs) — quadratic in horizon
because of its completed-job rescan — so its long-horizon cells are the
expensive part of a full run.

    PYTHONPATH=src python benchmarks/bench_sim.py [--smoke] [--out PATH]

--smoke caps the horizon at 1,000 ms (CI perf sanity: asserts the event
engine wins by >= 5x there; the full run's >= 10x criterion applies to
the 10,000 ms cell).
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.core.gang import BETask, RTTask
from repro.core.sim import Simulator, matrix_interference

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def fig5_style_taskset():
    """benchmarks/fig5_synthetic.py's taskset (restated: fresh task uids
    per call keep Simulator instances independent)."""
    t1 = RTTask("tau1", wcet=3.5, period=20, cores=(0, 1), prio=2,
                mem_budget=0.1)
    t2 = RTTask("tau2", wcet=6.5, period=30, cores=(2, 3), prio=1,
                mem_budget=0.1)
    bem = BETask("be_mem", cores=(0, 1, 2, 3), mem_rate=1.0)
    bec = BETask("be_cpu", cores=(0, 1, 2, 3), mem_rate=0.01)
    intf = matrix_interference({
        ("tau1", "tau2"): 2.0, ("tau2", "tau1"): 2.0,
        ("tau1", "be_mem"): 1.5, ("tau2", "be_mem"): 1.5,
    })
    return [t1, t2], [bem, bec], intf


def run_engine(dt, horizon: float):
    rts, bes, intf = fig5_style_taskset()
    sim = Simulator(4, rts, be_tasks=bes, interference=intf,
                    rt_gang_enabled=True, dt=dt, throttle_mode="reactive")
    t0 = time.perf_counter()
    r = sim.run(horizon)
    wall = time.perf_counter() - t0
    return r, wall


def bench_horizon(horizon: float, dt: float = 0.05) -> dict:
    e, e_wall = run_engine(None, horizon)
    q, q_wall = run_engine(dt, horizon)
    jobs = sum(len(v) for v in e.response_times.values())
    row = {
        "horizon_ms": horizon,
        "quantum_dt_ms": dt,
        "quantum_wall_s": round(q_wall, 4),
        "event_wall_s": round(e_wall, 4),
        "speedup": round(q_wall / e_wall, 2) if e_wall > 0 else None,
        "events": e.events,
        "events_per_sec": round(e.events / e_wall) if e_wall > 0 else None,
        "quantum_steps": int(round(horizon / dt)),
        "jobs_completed": jobs,
        "wcrt_quantum": {k: max(v) for k, v in q.response_times.items()},
        "wcrt_event": {k: max(v) for k, v in e.response_times.items()},
        "wcrt_max_gap_ms": round(max(
            abs(max(q.response_times[k]) - max(e.response_times[k]))
            for k in e.response_times), 5),
        "misses_equal": q.deadline_misses == e.deadline_misses,
    }
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short horizons only; assert >=5x at 1,000 ms")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_sim.json"))
    args = ap.parse_args()

    horizons = [120.0, 1000.0] if args.smoke \
        else [120.0, 1000.0, 10000.0]
    rows = []
    for h in horizons:
        row = bench_horizon(h)
        rows.append(row)
        print(json.dumps(row))

    out = {
        "bench": "sim_engines",
        "taskset": "fig5_synthetic (2 RT gangs + 2 BE, reactive throttle)",
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")

    last = rows[-1]
    target = 5.0 if args.smoke else 10.0
    assert last["misses_equal"], "engines disagree on deadline misses"
    assert last["speedup"] >= target, \
        f"speedup {last['speedup']}x below {target}x at {last['horizon_ms']}ms"
    print(f"OK: {last['speedup']}x at {last['horizon_ms']}ms "
          f"({last['events_per_sec']} events/s)")


if __name__ == "__main__":
    main()
