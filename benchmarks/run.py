"""Benchmark harness: one entry per paper table/figure + the roofline bench.
Prints ``name,value(s)`` lines; full objects go to stdout per-bench.

Also home of :func:`write_bench_json` — the single writer every
``BENCH_*.json`` goes through, so each artifact carries the same
provenance header (schema version, host fingerprint, git SHA) and the
bench scripts stop hand-rolling their own ``json.dump`` epilogues."""
import json
import os
import platform
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# bump when the shared header or any BENCH_*.json payload shape changes
# incompatibly (consumers: CI smoke checks, examples/)
BENCH_SCHEMA_VERSION = 2


def git_sha() -> "str | None":
    """HEAD commit of the repo the benches ran from (None outside a
    checkout — e.g. a source tarball)."""
    try:
        p = subprocess.run(["git", "rev-parse", "HEAD"], cwd=ROOT,
                           capture_output=True, text=True, timeout=10)
    except (OSError, subprocess.SubprocessError):
        return None
    sha = p.stdout.strip()
    return sha if p.returncode == 0 and sha else None


def write_bench_json(path: str, payload: dict, config=None) -> str:
    """Stamp the provenance header onto ``payload`` and write it.

    The header keys (``schema_version``, ``git_sha``, ``host`` — plus
    ``config`` / ``config_digest`` when a resolved ExperimentConfig is
    passed) are reserved: a payload supplying its own values for them
    is a bug, so they always win over the payload."""
    doc = dict(payload)
    doc["schema_version"] = BENCH_SCHEMA_VERSION
    doc["git_sha"] = git_sha()
    if config is not None:
        doc["config"] = config.to_dict()
        doc["config_digest"] = config.content_digest()
    doc["host"] = {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "cpu_count": os.cpu_count(),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return path


def main() -> None:
    t0 = time.time()
    from benchmarks import (fig1_parallelization, fig4_illustrative,
                            fig5_synthetic, fig6_dnn_cdf, table3_overhead,
                            roofline_bench)

    print("== fig4 (illustrative example, paper §III-E) ==")
    for r in fig4_illustrative.run():
        print(r)

    print("== fig5 (synthetic taskset traces, paper §V-B) ==")
    for r in fig5_synthetic.run(horizon=120.0):
        trace = r.pop("trace")
        print(r)
        print(trace.render_ascii(t_end=60.0, width=90))

    print("== fig1 (DNN parallelization + co-run, paper §II) ==")
    for r in fig1_parallelization.run():
        print(r)

    print("== fig6 (DNN latency CDFs: solo/cosched/rtgang, paper §V-C) ==")
    for k, v in fig6_dnn_cdf.run(duration=5.0).items():
        print(k, v)

    print("== table3 (scheduler overhead, paper §V-D) ==")
    for r in table3_overhead.run():
        print(r)

    print("== sim engines (event-driven vs fixed-quantum, smoke) ==")
    from benchmarks import bench_sim
    for h in (120.0, 1000.0):
        print(bench_sim.bench_horizon("fig5_4c", h))

    print("== roofline (per arch x shape x mesh; dry-run cache) ==")
    rows = roofline_bench.run()
    for r in rows:
        print(r)
    if not rows:
        print("(run `python -m repro.launch.sweep` to populate)")

    print(f"== done in {time.time()-t0:.1f}s ==")


if __name__ == '__main__':
    main()
