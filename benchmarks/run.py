"""Benchmark harness: one entry per paper table/figure + the roofline bench.
Prints ``name,value(s)`` lines; full objects go to stdout per-bench."""
import sys
import time


def main() -> None:
    t0 = time.time()
    from benchmarks import (fig1_parallelization, fig4_illustrative,
                            fig5_synthetic, fig6_dnn_cdf, table3_overhead,
                            roofline_bench)

    print("== fig4 (illustrative example, paper §III-E) ==")
    for r in fig4_illustrative.run():
        print(r)

    print("== fig5 (synthetic taskset traces, paper §V-B) ==")
    for r in fig5_synthetic.run(horizon=120.0):
        trace = r.pop("trace")
        print(r)
        print(trace.render_ascii(t_end=60.0, width=90))

    print("== fig1 (DNN parallelization + co-run, paper §II) ==")
    for r in fig1_parallelization.run():
        print(r)

    print("== fig6 (DNN latency CDFs: solo/cosched/rtgang, paper §V-C) ==")
    for k, v in fig6_dnn_cdf.run(duration=5.0).items():
        print(k, v)

    print("== table3 (scheduler overhead, paper §V-D) ==")
    for r in table3_overhead.run():
        print(r)

    print("== sim engines (event-driven vs fixed-quantum, smoke) ==")
    from benchmarks import bench_sim
    for h in (120.0, 1000.0):
        print(bench_sim.bench_horizon(h))

    print("== roofline (per arch x shape x mesh; dry-run cache) ==")
    rows = roofline_bench.run()
    for r in rows:
        print(r)
    if not rows:
        print("(run `python -m repro.launch.sweep` to populate)")

    print(f"== done in {time.time()-t0:.1f}s ==")


if __name__ == '__main__':
    main()
