"""Paper Table III: gang-scheduling overhead — cost of the pick_next path
(lock acquire/release + gang preemption bookkeeping) vs the disabled
baseline, in microseconds, as a function of preempted-gang size."""
import time

from repro.core.gang import RTTask, Thread
from repro.core.glock import GangScheduler

N = 100_000


def measure(n_threads_lowprio: int, enabled: bool = True) -> float:
    s = GangScheduler(max(4, n_threads_lowprio), enabled=enabled)
    lo = RTTask("lo", wcet=1, period=10,
                cores=tuple(range(n_threads_lowprio)), prio=1)
    hi = RTTask("hi", wcet=1, period=10, cores=(0,), prio=9)
    lo_th = {c: Thread(task=lo, core=c, index=c)
             for c in range(n_threads_lowprio)}
    hi_th = Thread(task=hi, core=0, index=0)

    t0 = time.perf_counter()
    for _ in range(N):
        # low-prio gang occupies its cores
        for c in range(n_threads_lowprio):
            s.pick_next_task_rt(c, None, lo_th[c])
        # high-prio job arrives on core 0 -> gang preemption
        s.pick_next_task_rt(0, None, hi_th)
        # hi finishes; lock released
        s.pick_next_task_rt(0, hi_th, None)
    dt = time.perf_counter() - t0
    return dt / N * 1e6  # usec per preemption cycle


def run():
    rows = []
    base = measure(1, enabled=False)
    rows.append({"scenario": "1-thread-lowprio (disabled)",
                 "usec_per_cycle": round(base, 3)})
    for n in (1, 2, 3, 4):
        rows.append({"scenario": f"{n}-thread-lowprio (RT-Gang)",
                     "usec_per_cycle": round(measure(n), 3)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
