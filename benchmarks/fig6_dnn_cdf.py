"""Paper Fig.6: DNN inference-time CDF under Solo / Co-Sched / RT-Gang on
the real gang executor (DAVE-2 as the RT gang; memory + cpu parallel
best-effort jobs like lbm/cutcp)."""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.deeppicar import Dave2Config
from repro.core.executor import BEJob, GangExecutor, RTJob
from repro.models.dave2 import make_dave2


def percentiles(xs):
    xs = np.asarray(xs) * 1e3
    if len(xs) == 0:
        return {}
    return {"p50_ms": round(float(np.percentile(xs, 50)), 3),
            "p95_ms": round(float(np.percentile(xs, 95)), 3),
            "p99_ms": round(float(np.percentile(xs, 99)), 3),
            "max_ms": round(float(np.max(xs)), 3),
            "n": len(xs)}


def run(duration=6.0, period_s=0.020):
    cfg = Dave2Config()
    params, fn = make_dave2(cfg)
    img = jnp.ones((1, *cfg.input_hw, 3), jnp.float32)
    fn(params, img).block_until_ready()         # compile

    mem = jnp.ones((1536, 1536), jnp.float32)
    mem_fn = jax.jit(lambda a: (a @ a).sum())
    mem_fn(mem).block_until_ready()
    cpu_fn = jax.jit(lambda x: jnp.sin(x).sum())
    cpu_arr = jnp.ones((4096,), jnp.float32)
    cpu_fn(cpu_arr).block_until_ready()

    def dnn_quantum(lane, idx):
        fn(params, img).block_until_ready()

    def mem_quantum(lane):
        mem_fn(mem).block_until_ready()

    def cpu_quantum(lane):
        cpu_fn(cpu_arr).block_until_ready()

    results = {}

    # Solo
    lat = []
    for _ in range(100):
        t0 = time.perf_counter()
        fn(params, img).block_until_ready()
        lat.append(time.perf_counter() - t0)
    results["solo"] = percentiles(lat)

    for mode, enabled, budget in (("cosched", False, 1e18),
                                  ("rtgang", True, 0.0)):
        ex = GangExecutor(n_lanes=2, enabled=enabled,
                          regulation_interval_s=0.01)
        n_jobs = int(duration / period_s) - 2
        ex.submit_rt(RTJob("dnn", dnn_quantum, lanes=(0,), prio=10,
                           period_s=period_s, budget_bytes=budget,
                           n_jobs=n_jobs))
        ex.submit_be(BEJob("lbm_mem", mem_quantum, lanes=(0, 1),
                           bytes_per_quantum=1536 * 1536 * 8.0))
        ex.submit_be(BEJob("cutcp_cpu", cpu_quantum, lanes=(0, 1),
                           bytes_per_quantum=4096 * 4.0))
        stats = ex.run(duration)
        # quantum *execution* time: trace segments labelled dnn
        stats_lat = [s.t1 - s.t0 for s in ex.trace.segments
                     if s.label == "dnn"]
        results[mode] = percentiles(np.asarray(stats_lat) / 1e3)
        results[mode]["be_quanta"] = stats["be_quanta"]
    return results


if __name__ == "__main__":
    for k, v in run().items():
        print(k, v)
