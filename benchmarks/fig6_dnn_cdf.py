"""Paper Fig.6: DNN inference-time CDF under Solo / Co-Sched / RT-Gang.

Two drivers:

* default — the real gang executor (DAVE-2 as the RT gang; memory + cpu
  parallel best-effort jobs like lbm/cutcp); wall-clock, needs JAX.
* ``--sim`` — the exact event engine (Simulator dt=None) at long
  horizons (default 10^6 ms, ROADMAP item 2): the modeled DNN gang vs a
  memory-hog best-effort co-runner, percentiles extracted with
  ``SimResult.percentiles`` (p50/p95/p99/p999). O(events) keeps a
  million-millisecond run in seconds.

    PYTHONPATH=src python benchmarks/fig6_dnn_cdf.py [--sim]
        [--horizon 1e6]
"""
import argparse
import time


def percentiles(xs):
    import numpy as np
    xs = np.asarray(xs) * 1e3
    if len(xs) == 0:
        return {}
    return {"p50_ms": round(float(np.percentile(xs, 50)), 3),
            "p95_ms": round(float(np.percentile(xs, 95)), 3),
            "p99_ms": round(float(np.percentile(xs, 99)), 3),
            "max_ms": round(float(np.max(xs)), 3),
            "n": len(xs)}


def run(duration=6.0, period_s=0.020):
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.configs.deeppicar import Dave2Config
    from repro.core.executor import BEJob, GangExecutor, RTJob
    from repro.models.dave2 import make_dave2

    cfg = Dave2Config()
    params, fn = make_dave2(cfg)
    img = jnp.ones((1, *cfg.input_hw, 3), jnp.float32)
    fn(params, img).block_until_ready()         # compile

    mem = jnp.ones((1536, 1536), jnp.float32)
    mem_fn = jax.jit(lambda a: (a @ a).sum())
    mem_fn(mem).block_until_ready()
    cpu_fn = jax.jit(lambda x: jnp.sin(x).sum())
    cpu_arr = jnp.ones((4096,), jnp.float32)
    cpu_fn(cpu_arr).block_until_ready()

    def dnn_quantum(lane, idx):
        fn(params, img).block_until_ready()

    def mem_quantum(lane):
        mem_fn(mem).block_until_ready()

    def cpu_quantum(lane):
        cpu_fn(cpu_arr).block_until_ready()

    results = {}

    # Solo
    lat = []
    for _ in range(100):
        t0 = time.perf_counter()
        fn(params, img).block_until_ready()
        lat.append(time.perf_counter() - t0)
    results["solo"] = percentiles(lat)

    for mode, enabled, budget in (("cosched", False, 1e18),
                                  ("rtgang", True, 0.0)):
        ex = GangExecutor(n_lanes=2, enabled=enabled,
                          regulation_interval_s=0.01)
        n_jobs = int(duration / period_s) - 2
        ex.submit_rt(RTJob("dnn", dnn_quantum, lanes=(0,), prio=10,
                           period_s=period_s, budget_bytes=budget,
                           n_jobs=n_jobs))
        ex.submit_be(BEJob("lbm_mem", mem_quantum, lanes=(0, 1),
                           bytes_per_quantum=1536 * 1536 * 8.0))
        ex.submit_be(BEJob("cutcp_cpu", cpu_quantum, lanes=(0, 1),
                           bytes_per_quantum=4096 * 4.0))
        stats = ex.run(duration)
        # quantum *execution* time: trace segments labelled dnn
        stats_lat = [s.t1 - s.t0 for s in ex.trace.segments
                     if s.label == "dnn"]
        results[mode] = percentiles(np.asarray(stats_lat) / 1e3)
        results[mode]["be_quanta"] = stats["be_quanta"]
    return results


def run_sim(horizon_ms: float = 1e6):
    """Fig.6-style latency CDFs through the exact event engine: the
    DeepPicar DNN gang (Table II numbers) against a memory-intensive
    best-effort co-runner, Solo / Co-Sched / RT-Gang. Returns per-mode
    percentile summaries straight from SimResult.percentiles."""
    from repro.core.gang import BETask, RTTask
    from repro.core.sim import Simulator, matrix_interference

    def taskset():
        # width-2 DNN gang: cores 2-3 stay free, so the lower-priority
        # gang and best-effort work can actually co-run (and interfere)
        # under Co-Sched. tau2's period is non-harmonic with the DNN's,
        # so the overlap phase drifts and the Co-Sched CDF spreads out —
        # the paper's Fig.6 shape.
        dnn = RTTask("dnn", wcet=7.6, period=17.0, cores=(0, 1),
                     prio=2, mem_budget=0.05)
        tau2 = RTTask("tau2", wcet=12.0, period=45.0, cores=(2, 3),
                      prio=1, mem_budget=0.05)
        bem = BETask("lbm_mem", cores=(0, 1, 2, 3), mem_rate=1.0)
        bec = BETask("cutcp_cpu", cores=(0, 1, 2, 3), mem_rate=0.01)
        intf = matrix_interference({("dnn", "lbm_mem"): 2.2,
                                    ("dnn", "tau2"): 1.6,
                                    ("tau2", "lbm_mem"): 1.9})
        return [dnn, tau2], [bem, bec], intf

    results = {}
    for mode, enabled, with_be in (("solo", True, False),
                                   ("cosched", False, True),
                                   ("rtgang", True, True)):
        rts, bes, intf = taskset()
        sim = Simulator(4, rts if with_be else rts[:1],
                        be_tasks=bes if with_be else (),
                        interference=intf, rt_gang_enabled=enabled,
                        dt=None, throttle_mode="reactive")
        t0 = time.perf_counter()
        r = sim.run(horizon_ms)
        p = r.percentiles("dnn")
        p["misses"] = r.deadline_misses["dnn"]
        p["events"] = r.events
        p["wall_s"] = round(time.perf_counter() - t0, 3)
        results[mode] = p
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--sim", action="store_true",
                    help="event-engine model at long horizons instead of "
                         "the real executor")
    ap.add_argument("--horizon", type=float, default=1e6,
                    help="--sim horizon in ms (default 10^6)")
    args = ap.parse_args()
    rows = run_sim(args.horizon) if args.sim else run()
    for k, v in rows.items():
        print(k, v)
