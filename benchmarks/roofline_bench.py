"""Roofline benchmark: reads the dry-run JSON cache (results/dryrun/) and
computes the three roofline terms per (arch x shape x mesh). This is the
beyond-paper perf artifact; run ``python -m repro.launch.sweep`` first to
populate the cache (hours on 1 CPU), else reports whatever cells exist."""
import glob
import json
import os

from repro.roofline.report import roofline_row

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "dryrun")


def run():
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            cell = json.load(f)
        if "flops_per_device" not in cell:   # skipped.json etc.
            continue
        rows.append(roofline_row(cell))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
