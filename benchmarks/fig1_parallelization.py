"""Paper Fig.1: (a) DAVE-2 DNN control-loop time vs #lanes (parallelized via
batch-split across worker lanes); (b) solo vs co-run slowdown with a
memory-intensive task. Real JAX execution on the host device."""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.deeppicar import Dave2Config
from repro.models.dave2 import make_dave2


def time_fn(fn, *args, iters=20):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3


def run():
    cfg = Dave2Config()
    params, fn = make_dave2(cfg)
    batch = 8
    img = jnp.ones((batch, *cfg.input_hw, 3), jnp.float32)

    # (a) parallelization: shard the frame batch over n worker "cores"
    # (vmap-chunks emulate per-core work; on TPU these are mesh lanes)
    rows = []
    base = time_fn(fn, params, img)
    for n in (1, 2, 4):
        chunk = batch // n
        def split_fn(p, x, n=n, chunk=chunk):
            outs = [fn(p, x[i * chunk:(i + 1) * chunk]) for i in range(n)]
            return jnp.concatenate(outs)
        t = time_fn(jax.jit(split_fn), params, img)
        rows.append({"bench": "fig1a", "cores": n, "loop_ms": round(t, 3)})

    # (b) co-run: DNN inference while a memory benchmark hammers the bus
    mem = jnp.ones((1024, 1024), jnp.float32)
    mem_fn = jax.jit(lambda a: (a * 1.000001 + a.T).sum())
    solo = time_fn(fn, params, img)

    import threading
    stop = []

    def hammer():
        while not stop:
            mem_fn(mem).block_until_ready()

    th = threading.Thread(target=hammer, daemon=True)
    th.start()
    corun = time_fn(fn, params, img, iters=10)
    stop.append(1)
    th.join(timeout=2)

    mem_solo = time_fn(mem_fn, mem)
    rows.append({"bench": "fig1b", "dnn_solo_ms": round(solo, 3),
                 "dnn_corun_ms": round(corun, 3),
                 "dnn_slowdown": round(corun / solo, 2),
                 "mem_solo_ms": round(mem_solo, 3)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
